"""Emulated voltage-scaled systolic accelerator for real inference traffic.

:class:`EmulatedAccelerator` closes the loop between the CAD flow and the
DNN stack: it is built *from* a :class:`repro.flow.FlowReport` (per-partition
calibrated rails, MAC→partition floorplan, Razor window) and then *executes*
matmuls the way the paper's hardware would — per-MAC arrival times scale
with the data-dependent switching activity of the streamed activations
(Sec. II-E), the Razor model classifies each MAC-cycle as OK / DETECTED /
SILENT, DETECTED flags cost a replay cycle (energy + latency, value
corrected), and SILENT failures corrupt the product through a pluggable
model from :mod:`repro.hwloop.inject`.

Arbitrary ``(M, K) @ (K, N)`` shapes are tiled onto the ``n x n`` array
weight-stationary: K splits into row tiles (resident weight rows), N into
column tiles.  Within a K-tile the Razor status tensor depends only on the
streamed activations and the rail map — never on the weights — so it is
classified once and shared by every column tile, exactly like
:class:`repro.core.systolic.SystolicSim`'s flags-only trial path.

Clean tiles (no SILENT entry) take the *ideal* kernel (``a_blk @ w_blk``),
which makes the emulator bit-identical to the ideal tiled product whenever
no fault is injected — the parity property ``tests/hwloop`` pins down.
Every call feeds the :class:`repro.hwloop.energy.EnergyLedger` regardless.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional, Tuple

import numpy as np

from ..core.partition import Floorplan
from ..core.power import PowerModel, model_for
from ..core.razor import (DETECTED, SILENT, RazorConfig, classify_arrival,
                          effective_arrival, streamed_activity)
from ..core.timing import TimingModel
from .energy import EnergyLedger
from .inject import get_corruption

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids a flow import cycle
    from ..flow.config import FlowConfig
    from ..flow.report import FlowReport


@dataclasses.dataclass
class MatmulTelemetry:
    """Per-call Razor/energy observables of one emulated matmul."""

    detected_p: np.ndarray          # (P,) DETECTED counts per partition
    silent_p: np.ndarray            # (P,) SILENT counts per partition
    macs_p: np.ndarray              # (P,) executed MAC ops per partition
    partition_flags: np.ndarray     # (P,) bool: Razor flag fired (DETECTED only)
    replay_cycles: int
    cycles: int
    rel_error: float                # ||C_emu - C_ideal|| / ||C_ideal||

    @property
    def detected_rate(self) -> np.ndarray:
        """(P,) DETECTED fraction of that partition's MAC ops."""
        return self.detected_p / np.maximum(self.macs_p, 1)


#: The paper's input-bit-fluctuation term, shared with ``SystolicSim`` (one
#: definition in :mod:`repro.core.razor` keeps the two bit-identical).
quantized_activity = streamed_activity


class EmulatedAccelerator:
    """A voltage-island systolic array emulated under real matmul traffic.

    ``rails`` is the live per-partition V_ccint vector — mutable, because the
    online loop (:class:`repro.hwloop.session.HwLoopSession`) lowers and
    raises rails mid-serve.  The floorplan fixes the MAC→partition map; the
    timing model fixes per-MAC nominal delays; the power model prices MACs.
    """

    def __init__(self, timing: TimingModel, floorplan: Floorplan,
                 razor: Optional[RazorConfig] = None,
                 power: Optional[PowerModel] = None,
                 rails: Optional[np.ndarray] = None,
                 corruption: str = "stale",
                 quant_bits: int = 16,
                 leak_frac: float = 0.05,
                 seed: int = 0):
        self.timing = timing
        self.floorplan = floorplan
        self.razor = razor or RazorConfig(clock_ns=timing.clock_ns)
        self.power = power or model_for(timing.tech.name)
        self.quant_bits = quant_bits
        self.corruption = corruption
        self._corrupt = get_corruption(corruption)
        self._part = floorplan.partition_of_mac()               # (n*n,)
        self.n_partitions = int(self._part.max()) + 1
        n = timing.n
        self._part_grid = self._part.reshape(n, n)
        if rails is None:
            rails = np.array([p.v_ccint for p in
                              sorted(floorplan.partitions,
                                     key=lambda p: p.index)])
        self.rails = np.asarray(rails, dtype=np.float64).copy()
        if self.rails.shape != (self.n_partitions,):
            raise ValueError(f"expected {self.n_partitions} rail voltages, "
                             f"got {self.rails.shape}")
        if np.isnan(self.rails).any():
            raise ValueError("rail voltages unset (NaN); pass rails= or use "
                             "a floorplan with voltages assigned")
        self._rng = np.random.default_rng(seed)
        self.ledger = EnergyLedger(power=self.power, clock_ns=timing.clock_ns,
                                   array_n=n, n_partitions=self.n_partitions,
                                   leak_frac=leak_frac)

    # -- construction from the CAD flow --------------------------------------

    @classmethod
    def from_flow(cls, report: "FlowReport", cfg: "FlowConfig", *,
                  rails: Optional[np.ndarray] = None,
                  **kw) -> "EmulatedAccelerator":
        """Build the device a :class:`FlowReport` describes: the config's
        timing model (deterministic in ``(array_n, tech, clock_ns, seed)``),
        the report's floorplan, and its calibrated runtime rails."""
        tm = TimingModel(n=cfg.array_n, clock_ns=cfg.clock_ns, tech=cfg.node,
                         seed=cfg.seed)
        kw.setdefault("power", model_for(cfg.tech, freq_mhz=cfg.freq_mhz,
                                         activity=cfg.activity))
        kw.setdefault("razor", RazorConfig(clock_ns=cfg.clock_ns))
        return cls(tm, report.floorplan,
                   rails=np.asarray(report.runtime_v) if rails is None
                   else rails, **kw)

    # -- rail control (the online loop's knobs) -------------------------------

    def set_rails(self, v: np.ndarray) -> None:
        v = np.asarray(v, dtype=np.float64)
        if v.shape != self.rails.shape:
            raise ValueError(f"expected {self.rails.shape[0]} rails, got {v.shape}")
        self.rails = v.copy()

    def set_partition_voltage(self, partition: int, v: float) -> None:
        self.rails[partition] = float(v)

    @property
    def v_map(self) -> np.ndarray:
        """(n, n) per-MAC voltage from the live rails."""
        return self.rails[self._part_grid]

    # -- emulated execution ---------------------------------------------------

    def matmul(self, a: np.ndarray, w: np.ndarray
               ) -> Tuple[np.ndarray, MatmulTelemetry]:
        """Emulate ``C = a @ w`` on the voltage-scaled array.

        ``a``: (M, K) activations, ``w``: (K, N) weights; K and N are tiled
        onto the ``n x n`` grid.  Returns the (possibly corrupted) product
        and the call's telemetry; the energy ledger is updated in place.
        """
        a = np.asarray(a, dtype=np.float64)
        w = np.asarray(w, dtype=np.float64)
        if a.ndim != 2 or w.ndim != 2 or a.shape[1] != w.shape[0]:
            raise ValueError(f"incompatible shapes {a.shape} @ {w.shape}")
        n = self.timing.n
        m_rows, k_dim = a.shape
        n_dim = w.shape[1]
        c = np.zeros((m_rows, n_dim), dtype=np.float64)

        p = self.n_partitions
        detected_p = np.zeros(p, dtype=np.int64)
        silent_p = np.zeros(p, dtype=np.int64)
        macs_p = np.zeros(p, dtype=np.int64)
        cycles = 0
        delays = self.timing.delays_at(self.v_map)              # (n, n)

        for ki in range(0, k_dim, n):
            a_blk = a[:, ki:ki + n]                             # (M, kb)
            kb = a_blk.shape[1]
            act = quantized_activity(a_blk, self.quant_bits)    # (M, kb)
            arrival = effective_arrival(delays[None, :kb, :],
                                        act[:, :, None], self.razor)
            status = classify_arrival(arrival, self.razor)      # (M, kb, n)
            for nj in range(0, n_dim, n):
                w_blk = w[ki:ki + n, nj:nj + n]                 # (kb, nb)
                nb = w_blk.shape[1]
                st = status[:, :, :nb]
                part = self._part_grid[:kb, :nb].reshape(-1)
                det = (st == DETECTED).sum(axis=0).reshape(-1)
                sil = st == SILENT
                sil_counts = sil.sum(axis=0).reshape(-1)
                detected_p += np.bincount(part, weights=det,
                                          minlength=p).astype(np.int64)
                silent_p += np.bincount(part, weights=sil_counts,
                                        minlength=p).astype(np.int64)
                macs_p += m_rows * np.bincount(part, minlength=p)
                if sil.any():
                    terms = a_blk[:, :, None] * w_blk[None, :, :]
                    c_blk = self._corrupt(terms, sil, self._rng)
                else:
                    # fault-free tile: the ideal kernel, bit for bit
                    c_blk = a_blk @ w_blk
                c[:, nj:nj + nb] += c_blk
                # weight-stationary pass: pipeline fill + M streamed rows + drain
                cycles += m_rows + kb + nb - 1

        replay_cycles = int(detected_p.sum())
        self.ledger.record(macs_p, self.rails, detected_p,
                           cycles + replay_cycles)
        if silent_p.sum() == 0:
            # no corruption was injected, so c IS the ideal tiled product —
            # don't pay a second full matmul just to measure a zero
            rel_error = 0.0
        else:
            c_true = a @ w
            denom = float(np.linalg.norm(c_true)) or 1.0
            rel_error = float(np.linalg.norm(c - c_true)) / denom
        tel = MatmulTelemetry(
            detected_p=detected_p, silent_p=silent_p, macs_p=macs_p,
            partition_flags=detected_p > 0,
            replay_cycles=replay_cycles,
            cycles=cycles + replay_cycles,
            rel_error=rel_error,
        )
        return c, tel
