"""Pluggable silent-corruption models for the emulated accelerator.

When a MAC's effective arrival time falls past the Razor shadow window
(``SILENT`` in :mod:`repro.core.razor`), the error is *invisible* to the
runtime scheme and some corrupted value reaches the output.  What that value
is depends on the microarchitecture; the literature models it three ways:

* ``"stale"``   — the paper's (and :class:`repro.core.systolic.SystolicSim`'s)
  semantics: the MAC's output register re-emits its previous-cycle partial
  sum, so silent rows inherit the psum of the last clean row above them
  (a per-column forward fill).
* ``"tedrop"``  — ThUnderVolt's TE-Drop (Zhang et al., 2018): the failing
  MAC's multiply is dropped and the partial sum bypasses it unchanged —
  equivalent to zeroing the failing rank-1 term.
* ``"bitflip"`` — a single mantissa bit of the affected accumulator output is
  flipped (classic SEU-style corruption used in undervolting studies such as
  Salami et al., 2020).

Every model is a pure function ``(terms, silent, rng) -> out`` where
``terms`` is the ``(M, K, N)`` rank-1 term tensor of one weight tile
(``terms[m, i, j] = a[m, i] * w[i, j]``), ``silent`` is the matching boolean
failure mask, and ``out`` is the ``(M, N)`` corrupted tile product.  Models
are registered by name so :class:`repro.flow.FlowConfig` can select them
declaratively (``hwloop_corruption``).
"""

from __future__ import annotations

from typing import Callable, Dict

import numpy as np

CorruptionFn = Callable[[np.ndarray, np.ndarray, np.random.Generator],
                        np.ndarray]

CORRUPTION_MODELS: Dict[str, CorruptionFn] = {}


def register_corruption(name: str):
    """Decorator: make a corruption model selectable by name."""

    def deco(fn: CorruptionFn) -> CorruptionFn:
        CORRUPTION_MODELS[name] = fn
        return fn

    return deco


def get_corruption(name: str) -> CorruptionFn:
    try:
        return CORRUPTION_MODELS[name]
    except KeyError:
        raise KeyError(f"unknown corruption model {name!r}; registered: "
                       f"{sorted(CORRUPTION_MODELS)}") from None


@register_corruption("stale")
def stale_psum(terms: np.ndarray, silent: np.ndarray,
               rng: np.random.Generator) -> np.ndarray:
    """Stale-register forward fill — the systolic simulator's semantics.

    A silent MAC re-emits its previous-cycle output, so the psum flowing past
    it is the one of the last clean streamed row; chained silent cycles keep
    inheriting from the last clean row above (``np.maximum.accumulate`` over
    the last-clean row index, exactly as in
    ``SystolicSim._propagate_vec``).
    """
    m_rows, k, _ = terms.shape
    row_ix = np.arange(m_rows)[:, None]
    out = np.zeros((m_rows, terms.shape[2]), dtype=np.float64)
    for i in range(k):
        out = out + terms[:, i, :]
        sil = silent[:, i, :]
        if sil.any():
            last = np.maximum.accumulate(np.where(sil, -1, row_ix), axis=0)
            filled = np.take_along_axis(out, np.maximum(last, 0), axis=0)
            out = np.where(sil, np.where(last >= 0, filled, 0.0), out)
    return out


@register_corruption("tedrop")
def te_drop(terms: np.ndarray, silent: np.ndarray,
            rng: np.random.Generator) -> np.ndarray:
    """TE-Drop: the failing MAC's rank-1 contribution is zeroed; the partial
    sum rides past it unchanged."""
    return np.where(silent, 0.0, terms).sum(axis=1)


@register_corruption("bitflip")
def bit_flip(terms: np.ndarray, silent: np.ndarray,
             rng: np.random.Generator, *, bit: int = 40) -> np.ndarray:
    """Flip one mantissa bit of every output element whose column saw a
    silent failure.  Bit 40 of the float64 mantissa gives a ~2^-12 relative
    perturbation — noticeable but finite (exponent bits would explode)."""
    out = np.ascontiguousarray(terms.sum(axis=1), dtype=np.float64)
    hit = silent.any(axis=1)
    if hit.any():
        raw = out.view(np.int64)
        raw ^= np.where(hit, np.int64(1) << bit, np.int64(0))
    return out
