"""Cycle/energy ledger for the emulated voltage-scaled accelerator.

Accounts three components per partition, on top of the calibrated
:class:`repro.core.power.PowerModel`:

* **dynamic** — every executed MAC costs ``E_mac(V_p)`` (the CVf² law fit to
  the paper's Table II, via :meth:`PowerModel.energy_per_mac_pj`);
* **replay**  — every DETECTED Razor flag re-executes its MAC one cycle
  later (Sec. II-E's one-cycle penalty), paying the same per-MAC energy
  again plus a cycle of latency;
* **leakage** — a rail-independent static floor, modelled as a fixed
  fraction of the array's nominal dynamic power integrated over the elapsed
  cycles (tool power reports mix in exactly such a component — see
  ``core/power.py``'s discussion of why reductions don't track a pure V²
  law).

The ledger is the accumulation point the serve engine, the ``hwloop`` flow
stage and the benchmarks all read: ``energy_per_token_j`` /
``energy_per_mac_j`` / ``replay_rate``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

from ..core.power import PowerModel


@dataclasses.dataclass
class EnergyLedger:
    power: PowerModel
    clock_ns: float
    array_n: int
    n_partitions: int
    leak_frac: float = 0.05          # static leakage as a fraction of nominal dynamic power

    macs_p: np.ndarray = dataclasses.field(init=False)
    replays_p: np.ndarray = dataclasses.field(init=False)
    cycles: int = dataclasses.field(default=0, init=False)
    tokens: int = dataclasses.field(default=0, init=False)
    dynamic_j: float = dataclasses.field(default=0.0, init=False)
    replay_j: float = dataclasses.field(default=0.0, init=False)

    def __post_init__(self) -> None:
        self.macs_p = np.zeros(self.n_partitions, dtype=np.int64)
        self.replays_p = np.zeros(self.n_partitions, dtype=np.int64)

    # -- accumulation --------------------------------------------------------

    def record(self, macs_p: np.ndarray, rails: np.ndarray,
               replays_p: np.ndarray, cycles: int) -> None:
        """Account one emulated matmul: per-partition MAC counts at the
        current rail voltages, per-partition replay counts, elapsed cycles
        (including the replay cycles)."""
        macs_p = np.asarray(macs_p, dtype=np.int64)
        replays_p = np.asarray(replays_p, dtype=np.int64)
        e_mac_j = np.array([self.power.energy_per_mac_pj(float(v))
                            for v in np.asarray(rails)]) * 1e-12
        self.dynamic_j += float((macs_p * e_mac_j).sum())
        self.replay_j += float((replays_p * e_mac_j).sum())
        self.macs_p += macs_p
        self.replays_p += replays_p
        self.cycles += int(cycles)

    def add_tokens(self, n: int) -> None:
        """Attribute the energy recorded so far to ``n`` more served tokens."""
        self.tokens += int(n)

    # -- derived -------------------------------------------------------------

    @property
    def leakage_j(self) -> float:
        """Static floor: ``leak_frac`` of nominal dynamic power over the
        elapsed emulated wall-clock."""
        p_leak_w = self.leak_frac * self.power.baseline_mw(self.array_n) * 1e-3
        return float(p_leak_w * self.cycles * self.clock_ns * 1e-9)

    @property
    def total_j(self) -> float:
        return self.dynamic_j + self.replay_j + self.leakage_j

    @property
    def total_macs(self) -> int:
        return int(self.macs_p.sum())

    @property
    def replay_cycles(self) -> int:
        return int(self.replays_p.sum())

    @property
    def replay_rate(self) -> float:
        """DETECTED replays per executed MAC (0 when nothing ran yet)."""
        return float(self.replay_cycles / max(self.total_macs, 1))

    @property
    def energy_per_mac_j(self) -> Optional[float]:
        if self.total_macs == 0:
            return None
        return float(self.total_j / self.total_macs)

    @property
    def energy_per_token_j(self) -> Optional[float]:
        if self.tokens == 0:
            return None
        return float(self.total_j / self.tokens)

    def summary(self) -> Dict[str, Any]:
        """Plain-JSON-serializable snapshot (the telemetry payload)."""
        return {
            "dynamic_j": self.dynamic_j,
            "replay_j": self.replay_j,
            "leakage_j": self.leakage_j,
            "total_j": self.total_j,
            "cycles": self.cycles,
            "tokens": self.tokens,
            "macs": self.total_macs,
            "macs_per_partition": self.macs_p.tolist(),
            "replays_per_partition": self.replays_p.tolist(),
            "replay_cycles": self.replay_cycles,
            "replay_rate": self.replay_rate,
            "energy_per_mac_j": self.energy_per_mac_j,
            "energy_per_token_j": self.energy_per_token_j,
        }
