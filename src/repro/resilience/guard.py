"""ABFT-guarded GEMM execution: :class:`GuardedBackend`.

The emulated accelerator's SILENT corruption modes (stale / TE-Drop /
bitflip, :mod:`repro.hwloop.inject`) are by definition invisible to the
Razor replay path — at near-threshold rails a corrupted product flows
straight into model outputs with no flag.  ``GuardedBackend`` wraps ANY
:class:`~repro.backend.base.MatmulBackend` and closes that hole with
algorithm-based fault tolerance (Huang & Abraham, 1984; the standard ABFT
treatment for GEMM on unreliable hardware — Salami et al.'s undervolted
FPGAs motivate exactly this guard):

* ``mode="abft"``     — row/column checksum verification: the product's row
  and column sums are checked against two cheap GEMVs computed on the
  (trusted) host in float64.  O(MK + KN + MN) extra work for an O(MNK)
  product.  A single corrupted element shows up as exactly one bad row i
  and one bad column j with matching residuals — it is located and
  corrected in place without re-execution.
* ``mode="freivalds"``— Freivalds' probabilistic probe: one seeded ±1
  vector, ``C @ x`` vs ``A @ (B @ x)``.  Detection only (no localization),
  about a third of the ABFT cost; a corruption escapes one probe with
  probability <= 1/2, so ``probes=k`` drives the miss rate to 2^-k.
* ``mode="off"``      — transparent pass-through (measurement baseline).

On an uncorrectable mismatch the guard walks an escalation ladder:

1. bounded re-execution (``max_retries``) — clears transient faults;
2. rail heal — the detected corruption is fed to the attached
   :class:`~repro.hwloop.session.HwLoopSession` watchdog as all-partitions
   flags until its patience recalibrates the rails (the PR-4 heal path), or
   straight to the device's nominal rails when no session is attached.
   Deterministic undervolt faults survive retries; healing removes their
   cause, and the re-executed product at healthy rails is bit-identical to
   the ideal backend (the emulator's clean-tile parity property);
3. policy — ``fail_open`` returns the best product seen with
   ``guard_uncorrected`` telemetry; ``fail_closed`` raises
   :class:`GuardError`.

All guard activity lands in the ``guard_*`` counters of
:class:`~repro.backend.base.BackendTelemetry`, so the serve engine's
per-step pops surface detection/correction/heal rates per decode step.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import numpy as np

from ..backend.base import (BackendTelemetry, MatmulBackend, get_backend,
                            register_backend)

MODES = ("off", "freivalds", "abft")
POLICIES = ("fail_open", "fail_closed")


class GuardError(RuntimeError):
    """Raised under ``policy="fail_closed"`` when the escalation ladder
    cannot produce a verified product.

    When an ``ObsBus`` is attached, :attr:`flight` carries the flight
    recorder's ring (the last N step/guard/heal events, oldest first) so
    catchers can dump an NDJSON post-mortem without reaching back into
    the engine."""

    flight: list = []


@dataclasses.dataclass
class _Verdict:
    """One verification pass over a candidate product."""

    ok: bool
    bad_rows: np.ndarray            # indices of rows failing the checksum
    bad_cols: np.ndarray            # indices of cols failing the checksum
    row_err: np.ndarray             # (M,) row-sum residuals
    col_err: np.ndarray             # (N,) col-sum residuals


class GuardedBackend(MatmulBackend):
    """ABFT wrapper conforming to the ``MatmulBackend`` protocol.

    ``inner`` is any backend name or instance; the guard composes at the
    ``_execute`` level, so the shared precision pipeline (including the
    int8 quantize/dequant path) runs ONCE at the guard and the inner
    backend sees the same integer-valued operands it would unguarded —
    checksums over integer-valued float data are exact, which is what makes
    the bit-identical restoration guarantee testable.
    """

    is_guarded = True

    def __init__(self, inner: Any = "emulated", *, mode: str = "abft",
                 policy: str = "fail_closed", max_retries: int = 2,
                 probes: int = 2, tol: float = 1e-6, seed: int = 0,
                 heal: bool = True, session=None):
        super().__init__()
        if mode not in MODES:
            raise ValueError(f"unknown guard mode {mode!r}; known: {MODES}")
        if policy not in POLICIES:
            raise ValueError(f"unknown guard policy {policy!r}; "
                             f"known: {POLICIES}")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        if probes < 1:
            raise ValueError(f"probes must be >= 1, got {probes}")
        self.inner = get_backend(inner)
        self.mode = mode
        self.policy = policy
        self.max_retries = int(max_retries)
        self.probes = int(probes)
        self.tol = float(tol)
        self.heal = bool(heal)
        self.session = session
        self.name = f"guarded[{self.inner.name}]"
        self._rng = np.random.default_rng(seed)

    # -- wiring ---------------------------------------------------------------

    @property
    def accel(self):
        """Delegate to the inner backend's live device (when it has one), so
        the serve engine's hwloop adapter sees through the guard."""
        return self.inner.accel

    def attach_session(self, session) -> None:
        """Bind the hwloop session whose watchdog the heal path drives (the
        serve engine calls this when both guard and session are present)."""
        self.session = session

    def _obs_event(self, name: str, **attrs) -> None:
        """Guard escalation trace (no-op without an attached ObsBus).
        Emitted only on detection-path rungs, so the verified hot path
        pays nothing."""
        if self._obs is not None:
            self._obs.event(name, backend=self.inner.name, mode=self.mode,
                            **attrs)

    def add_tokens(self, n: int) -> None:
        self.inner.add_tokens(n)

    # -- verification ---------------------------------------------------------

    def _abft_verify(self, a64: np.ndarray, b64: np.ndarray,
                     out64: np.ndarray) -> _Verdict:
        row_ref = a64 @ b64.sum(axis=1)              # (M,) trusted GEMV
        col_ref = a64.sum(axis=0) @ b64              # (N,) trusted GEMV
        row_err = out64.sum(axis=1) - row_ref
        col_err = out64.sum(axis=0) - col_ref
        # scale-aware tolerance: exact-zero for integer-valued operands is
        # never reached by float inputs, so bound by the checksum's own
        # magnitude envelope
        row_tol = self.tol * (np.abs(a64) @ np.abs(b64).sum(axis=1) + 1.0)
        col_tol = self.tol * (np.abs(a64).sum(axis=0) @ np.abs(b64) + 1.0)
        bad_rows = np.flatnonzero(np.abs(row_err) > row_tol)
        bad_cols = np.flatnonzero(np.abs(col_err) > col_tol)
        return _Verdict(ok=(bad_rows.size == 0 and bad_cols.size == 0),
                        bad_rows=bad_rows, bad_cols=bad_cols,
                        row_err=row_err, col_err=col_err)

    def _freivalds_verify(self, a64: np.ndarray, b64: np.ndarray,
                          out64: np.ndarray) -> bool:
        n = b64.shape[1]
        scale = self.tol * (np.abs(a64) @ np.abs(b64).sum(axis=1) + 1.0)
        for _ in range(self.probes):
            x = self._rng.integers(0, 2, size=n).astype(np.float64) * 2 - 1
            if np.any(np.abs(out64 @ x - a64 @ (b64 @ x)) > scale):
                return False
        return True

    def _verify(self, a64, b64, out64) -> _Verdict:
        if self.mode == "freivalds":
            ok = self._freivalds_verify(a64, b64, out64)
            empty = np.empty(0, np.int64)
            return _Verdict(ok=ok, bad_rows=empty, bad_cols=empty,
                            row_err=np.zeros(out64.shape[0]),
                            col_err=np.zeros(out64.shape[1]))
        return self._abft_verify(a64, b64, out64)

    # -- escalation ladder ----------------------------------------------------

    def _try_correct(self, out64: np.ndarray, v: _Verdict) -> bool:
        """Single-element locate-and-correct: one bad row x one bad column
        with matching residuals pins the corruption to C[i, j]."""
        if self.mode != "abft" or v.bad_rows.size != 1 or v.bad_cols.size != 1:
            return False
        i, j = int(v.bad_rows[0]), int(v.bad_cols[0])
        delta_r, delta_c = v.row_err[i], v.col_err[j]
        scale = max(abs(delta_r), abs(delta_c), 1.0)
        if abs(delta_r - delta_c) > self.tol * scale:
            return False                  # residuals disagree: >1 element hit
        out64[i, j] -= delta_r
        return True

    def _heal_rails(self) -> bool:
        """Re-rail the inner device: watchdog recalibration when a session is
        attached (detected corruption counts as an all-partitions event),
        else straight to the tech node's nominal voltage."""
        accel = getattr(self.inner, "accel", None)
        if self.session is not None:
            flags = np.ones(self.session.n_partitions, dtype=bool)
            for _ in range(int(self.session.watchdog.patience) + 1):
                if self.session.observe_flags(flags):
                    return True
            return False
        if accel is None:
            return False
        accel.set_rails(np.full(accel.n_partitions,
                                float(accel.timing.tech.v_nom)))
        return True

    # -- execution ------------------------------------------------------------

    def _execute(self, a: np.ndarray, b: np.ndarray
                 ) -> Tuple[np.ndarray, BackendTelemetry]:
        out, tel = self.inner._execute(a, b)
        if self.mode == "off":
            return out, tel
        a64 = np.asarray(a, dtype=np.float64)
        b64 = np.asarray(b, dtype=np.float64)
        out64 = np.asarray(out, dtype=np.float64).copy()
        tel.guard_checks += 1
        v = self._verify(a64, b64, out64)
        if v.ok:
            return out64, tel
        tel.guard_detected += 1
        self._obs_event("guard_detect", bad_rows=int(v.bad_rows.size),
                        bad_cols=int(v.bad_cols.size))

        if self._try_correct(out64, v):
            tel.guard_checks += 1
            if self._verify(a64, b64, out64).ok:
                tel.guard_corrected += 1
                self._obs_event("guard_correct")
                return out64, tel

        # rung 1: bounded re-execution (clears transient faults; a
        # deterministic undervolt fault reproduces and falls through)
        for retry in range(self.max_retries):
            out_r, tel_r = self.inner._execute(a, b)
            tel.merge(tel_r)
            tel.calls -= 1              # one protocol call, several executions
            tel.guard_retries += 1
            self._obs_event("guard_retry", attempt=retry + 1)
            out64 = np.asarray(out_r, dtype=np.float64).copy()
            tel.guard_checks += 1
            v = self._verify(a64, b64, out64)
            if v.ok:
                return out64, tel
            if self._try_correct(out64, v):
                tel.guard_checks += 1
                if self._verify(a64, b64, out64).ok:
                    tel.guard_corrected += 1
                    self._obs_event("guard_correct")
                    return out64, tel

        # rung 2: heal the rails, then one more execution at health
        if self.heal and self._heal_rails():
            tel.guard_heals += 1
            self._obs_event("guard_heal",
                            via="watchdog" if self.session is not None
                            else "nominal")
            out_r, tel_r = self.inner._execute(a, b)
            tel.merge(tel_r)
            tel.calls -= 1
            out64 = np.asarray(out_r, dtype=np.float64).copy()
            tel.guard_checks += 1
            if self._verify(a64, b64, out64).ok:
                return out64, tel

        # rung 3: policy
        tel.guard_uncorrected += 1
        self._obs_event("guard_uncorrected", policy=self.policy)
        if self.policy == "fail_closed":
            err = GuardError(
                f"unverified product after {self.max_retries} retries "
                f"(mode={self.mode}, heal={self.heal}, "
                f"inner={self.inner.name})")
            if self._obs is not None:
                # hand the black box to the catcher: the flight recorder
                # ring (ending in this escalation) rides on the exception
                err.flight = self._obs.recorder.to_list()
            raise err
        return out64, tel

    # -- telemetry ------------------------------------------------------------

    def summary(self) -> Dict[str, Any]:
        out = super().summary()
        out["mode"] = self.mode
        out["policy"] = self.policy
        inner = self.inner.summary()
        out["inner"] = inner
        # surface the inner energy accounting at the top level so guarded
        # serving keeps the J/token telemetry consumers expect
        for key in ("energy_per_token_j", "tokens"):
            if key in inner:
                out[key] = inner[key]
        return out


def _make_guarded(inner: Any = "emulated", **kw: Any) -> GuardedBackend:
    return GuardedBackend(inner, **kw)


register_backend("guarded", _make_guarded)
