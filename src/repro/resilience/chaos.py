"""Seeded fault-injection campaign over the full serving stack.

Each scenario scripts ONE failure mode end-to-end — through
:class:`~repro.serve.engine.ServeEngine` (and, where the failure involves
the wire, the asyncio HTTP frontend + client) — and checks the graceful-
degradation contract:

* no crash: the engine drains, the pump thread survives, `/healthz` answers;
* no corrupted completed stream: every stream reported ``completed`` carries
  tokens bit-identical to the ideal-backend reference decode;
* honest accounting: every request lands in exactly one terminal bucket
  (completed / truncated / shed / cancelled), and sheds carry their reason.

Scenarios (all seeded, all scale down under ``fast=True``):

``silent_burst``     repeated rail collapses into the silent-corruption
                     region mid-serve; the ABFT guard must detect, heal and
                     keep every stream clean through multiple bursts.
``rail_droop``       an HTTP serve with one mid-flight droop of every rail;
                     clients must stream to completion with clean tokens.
``watchdog_delay``   a high-patience watchdog delays recalibration; the
                     guard's heal loop must still restore rails within one
                     guarded GEMM.
``slow_decode``      a stalled engine behind a request-level timeout; the
                     server must cancel, answer 503, and keep serving.
``client_disconnect``a client drops mid-stream; the engine must reap the
                     slot and finish the remaining streams.
``overload_shed``    a burst into a bounded queue; 503s must carry
                     ``Retry-After`` and the shed accounting must balance.

``run_campaign`` executes all of them and aggregates a :class:`ChaosReport`
(the ``BENCH_resilience.json`` payload and the CI resilience-smoke gate).
"""

from __future__ import annotations

import asyncio
import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from ..backend.base import ensure_host_callback_capacity
from ..backend.impls import EmulatedBackend
from .guard import GuardedBackend

#: Rail voltage deep in the crash region of the vtr-22nm node — every
#: partition produces SILENT corruption there (tests/hwloop pins this down).
V_CRASH = 0.58


@dataclasses.dataclass
class ScenarioResult:
    name: str
    ok: bool
    violations: List[str]
    details: Dict[str, Any]

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ChaosReport:
    results: List[ScenarioResult]
    elapsed_s: float

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.results)

    @property
    def corrupted_streams(self) -> int:
        return sum(r.details.get("corrupted_streams", 0)
                   for r in self.results)

    @property
    def crashes(self) -> int:
        return sum(r.details.get("crashed", 0) for r in self.results)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "ok": self.ok,
            "crashes": self.crashes,
            "corrupted_streams": self.corrupted_streams,
            "elapsed_s": self.elapsed_s,
            "scenarios": [r.to_dict() for r in self.results],
        }


# ---------------------------------------------------------------------------
# Shared plumbing
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def _model():
    import jax

    from ..configs import get_config
    from ..models import model_api

    cfg = get_config("starcoder2-3b", smoke=True)
    api = model_api(cfg)
    return cfg, api.init_params(jax.random.PRNGKey(0))


def _prompts(n: int, seed: int) -> List[List[int]]:
    rng = np.random.default_rng(seed)
    return [rng.integers(3, 64, size=int(rng.integers(2, 5))).tolist()
            for _ in range(n)]


def _guarded_engine(session=None, corruption: str = "bitflip",
                    guard_mode: str = "abft",
                    guard_policy: str = "fail_closed",
                    **engine_kw):
    """Continuous engine over a guarded emulated backend at nominal rails.
    Extra keywords go to the engine (``policy=``, ``max_pending=``, ...)."""
    from ..obs import ObsBus
    from ..serve import ServeEngine

    cfg, params = _model()
    if session is not None:
        inner = EmulatedBackend(session.accel)
        engine_kw["hwloop"] = session
    else:
        inner = EmulatedBackend.nominal(corruption=corruption)
    guard = GuardedBackend(inner, mode=guard_mode, policy=guard_policy)
    # every chaos engine flies with a black box: the last 128 step/guard/
    # heal events, dumped into the scenario's details when it turns red
    engine_kw.setdefault("obs", ObsBus(recorder_capacity=128))
    eng = ServeEngine(cfg, params, slots=2, max_len=32, backend=guard,
                      **engine_kw)
    return eng, guard


def _flight(eng, violations: List[str]) -> Dict[str, Any]:
    """Failed scenarios ship the engine's flight-recorder ring in their
    details, so a red campaign is diagnosable from the
    ``BENCH_resilience.json`` CI artifact alone."""
    if not violations:
        return {}
    recorder = getattr(getattr(eng, "obs", None), "recorder", None)
    if recorder is None:
        return {}
    return {"flight_recorder": recorder.to_list()}


@functools.lru_cache(maxsize=8)
def _ideal_reference(prompts_key: tuple, max_new: int) -> tuple:
    """Greedy decode of the same workload on the ideal backend — the
    bit-exact truth each completed stream is compared against."""
    from ..serve import Request, ServeEngine

    cfg, params = _model()
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=max_new)
            for i, p in enumerate(prompts_key)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    return tuple(tuple(r.out_tokens) for r in reqs)


def _drain_scripted(eng, script: Optional[Callable[[int, Any], None]] = None,
                    max_steps: int = 2000):
    """Drive the engine step by step, invoking ``script(step, engine)``
    before each iteration (the fault-injection hook), then finalize stats."""
    steps = 0
    while not eng.scheduler.drained() and steps < max_steps:
        if script is not None:
            script(steps, eng)
        eng.step()
        steps += 1
    return eng.run_until_drained(max_steps=max_steps)


def _check_streams(reqs, ref, violations: List[str]) -> int:
    """Every completed request must match the ideal reference bit for bit.
    Returns the number of corrupted completed streams."""
    corrupted = 0
    for i, r in enumerate(reqs):
        if r.status != "completed":
            violations.append(f"request {r.uid} ended {r.status}, "
                              f"expected completed")
            continue
        if tuple(r.out_tokens) != ref[i]:
            corrupted += 1
            violations.append(f"request {r.uid} completed with corrupted "
                              f"tokens {r.out_tokens} != {list(ref[i])}")
    return corrupted


def _submit_all(eng, prompts: Sequence[Sequence[int]], max_new: int):
    from ..serve import Request

    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    return reqs


# ---------------------------------------------------------------------------
# Engine-level scenarios
# ---------------------------------------------------------------------------


def _scn_silent_burst(fast: bool, seed: int) -> ScenarioResult:
    """Collapse every rail into the silent-corruption region repeatedly
    mid-serve.  The guard heals each burst; streams stay bit-clean."""
    n_req, max_new = (3, 4) if fast else (6, 8)
    prompts = _prompts(n_req, seed)
    ref = _ideal_reference(tuple(tuple(p) for p in prompts), max_new)
    eng, guard = _guarded_engine(corruption="bitflip")
    reqs = _submit_all(eng, prompts, max_new)
    burst_steps = (1, 4)       # off the admission steps: hit DECODE GEMMs
    accel = guard.accel

    def script(step: int, _eng) -> None:
        if step in burst_steps:                      # repeated rail collapse
            accel.set_rails(np.full(accel.n_partitions, V_CRASH))

    violations: List[str] = []
    crashed = 0
    try:
        stats = _drain_scripted(eng, script)
    except Exception as e:          # noqa: BLE001 - the scenario's verdict
        crashed = 1
        violations.append(f"engine crashed: {type(e).__name__}: {e}")
        stats = eng.stats
    corrupted = _check_streams(reqs, ref, violations) if not crashed else 0
    tel = guard.total
    if not crashed:
        if tel.guard_detected == 0:
            violations.append("bursts injected but the guard detected "
                              "nothing")
        if tel.guard_heals == 0:
            violations.append("deterministic faults require rail heals; "
                              "none happened")
        if tel.guard_uncorrected:
            violations.append(f"{tel.guard_uncorrected} GEMMs left "
                              f"uncorrected under fail_closed")
        if not stats.guard_step_events:
            violations.append("decode-step guard telemetry is empty though "
                              "bursts hit decode steps")
    return ScenarioResult(
        name="silent_burst", ok=not violations, violations=violations,
        details={
            "crashed": crashed, "corrupted_streams": corrupted,
            "completed": stats.completed, "requests": n_req,
            "guard_checks": tel.guard_checks,
            "guard_detected": tel.guard_detected,
            "guard_corrected": tel.guard_corrected,
            "guard_retries": tel.guard_retries,
            "guard_heals": tel.guard_heals,
            "guard_uncorrected": tel.guard_uncorrected,
            "guard_step_events": len(stats.guard_step_events),
            **_flight(eng, violations),
        })


def _scn_watchdog_delay(fast: bool, seed: int) -> ScenarioResult:
    """A high-patience watchdog delays recalibration.  The guard's heal loop
    feeds it corruption evidence until it acts — still within a single
    guarded GEMM — so streams stay clean despite the sluggish watchdog."""
    from ..flow import FlowConfig
    from ..hwloop import HwLoopSession

    n_req, max_new = (3, 4) if fast else (5, 8)
    patience = 5
    session = HwLoopSession(
        FlowConfig(array_n=8, tech="vtr-22nm", max_trials=8, seed=2021),
        probe_rows=8, rail_margin=0.02, patience=patience)
    prompts = _prompts(n_req, seed + 1)
    ref = _ideal_reference(tuple(tuple(p) for p in prompts), max_new)
    eng, guard = _guarded_engine(session=session)
    reqs = _submit_all(eng, prompts, max_new)
    accel = guard.accel
    dropped = {"done": False}

    def script(step: int, _eng) -> None:
        if step == 2 and not dropped["done"]:        # one mid-serve collapse
            dropped["done"] = True
            accel.set_rails(np.full(accel.n_partitions, V_CRASH))

    violations: List[str] = []
    crashed = 0
    try:
        stats = _drain_scripted(eng, script)
    except Exception as e:          # noqa: BLE001 - the scenario's verdict
        crashed = 1
        violations.append(f"engine crashed: {type(e).__name__}: {e}")
        stats = eng.stats
    corrupted = _check_streams(reqs, ref, violations) if not crashed else 0
    tel = guard.total
    if not crashed:
        if tel.guard_heals == 0:
            violations.append("guard never healed through the watchdog")
        if session.recalibrations == 0:
            violations.append("watchdog never recalibrated despite "
                              "corruption evidence")
        if tel.guard_uncorrected:
            violations.append(f"{tel.guard_uncorrected} uncorrected GEMMs")
    return ScenarioResult(
        name="watchdog_delay", ok=not violations, violations=violations,
        details={
            "crashed": crashed, "corrupted_streams": corrupted,
            "completed": stats.completed, "requests": n_req,
            "watchdog_patience": patience,
            "recalibrations": session.recalibrations,
            "guard_detected": tel.guard_detected,
            "guard_heals": tel.guard_heals,
            "guard_uncorrected": tel.guard_uncorrected,
            **_flight(eng, violations),
        })


# ---------------------------------------------------------------------------
# HTTP scenarios
# ---------------------------------------------------------------------------


def _scn_rail_droop(fast: bool, seed: int) -> ScenarioResult:
    """Full-stack: concurrent HTTP clients stream from a guarded emulated
    engine whose rails droop mid-serve.  Every stream must complete with
    bit-clean tokens and the pump must survive."""
    from ..server import ServeFrontend, get_json, stream_generate

    n_req, max_new = (3, 4) if fast else (6, 8)
    prompts = _prompts(n_req, seed + 2)
    ref = _ideal_reference(tuple(tuple(p) for p in prompts), max_new)
    eng, guard = _guarded_engine(corruption="stale")
    accel = guard.accel
    real_step = eng.step
    dropped = {"at": 2, "count": 0, "steps": 0}

    def droop_step(*a, **kw):
        dropped["steps"] += 1
        if dropped["steps"] == dropped["at"]:
            dropped["count"] += 1
            accel.set_rails(np.full(accel.n_partitions, V_CRASH))
        return real_step(*a, **kw)

    eng.step = droop_step

    async def scenario():
        frontend = ServeFrontend(eng)
        host, port = await frontend.start()
        results = await asyncio.gather(*[
            stream_generate(host, port, p, max_new_tokens=max_new)
            for p in prompts])
        health = await get_json(host, port, "/healthz")
        await frontend.drain()
        await frontend.close()
        return results, health

    violations: List[str] = []
    crashed = 0
    results, health = [], {}
    try:
        results, health = asyncio.run(scenario())
    except Exception as e:          # noqa: BLE001 - the scenario's verdict
        crashed = 1
        violations.append(f"stack crashed: {type(e).__name__}: {e}")
    corrupted = 0
    if not crashed:
        if not health.get("pump_alive", False):
            violations.append("pump thread died")
        if dropped["count"] == 0:
            violations.append("the droop never fired (serve too short)")
        for i, res in enumerate(results):
            if not (res.ok and res.status == "completed"):
                violations.append(f"stream {i} ended "
                                  f"{res.status}/{res.http_status}")
            elif tuple(res.tokens) != ref[i]:
                corrupted += 1
                violations.append(f"stream {i} completed with corrupted "
                                  f"tokens")
    tel = guard.total
    if not crashed and tel.guard_detected == 0:
        violations.append("rails drooped but the guard saw nothing")
    return ScenarioResult(
        name="rail_droop", ok=not violations, violations=violations,
        details={
            "crashed": crashed, "corrupted_streams": corrupted,
            "requests": n_req, "droops": dropped["count"],
            "guard_detected": tel.guard_detected,
            "guard_heals": tel.guard_heals,
            "guard_uncorrected": tel.guard_uncorrected,
            **_flight(eng, violations),
        })


def _scn_slow_decode(fast: bool, seed: int) -> ScenarioResult:
    """A stalled decode behind a server-side request timeout: the slow
    request is cancelled with a 503, the engine reaps its slot, and the
    server keeps serving afterwards."""
    from ..server import ServeFrontend, get_json, stream_generate

    from ..serve import Request

    eng, guard = _guarded_engine()
    real_step = eng.step
    stalling = {"on": False, "stall_s": 0.0}

    def stalled_step(*a, **kw):
        if stalling["on"]:
            time.sleep(stalling["stall_s"])         # a wedged model step
        return real_step(*a, **kw)

    eng.step = stalled_step

    # warm the jit caches engine-side (the frontend timeout must not apply
    # to compilation), then time a steady-state 1-token request so the
    # timeout/stall pair scales with this host's real step latency
    for uid in (10_000, 10_001):
        t0 = time.perf_counter()
        eng.submit(Request(uid=uid, prompt=[3, 4], max_new_tokens=1))
        eng.run_until_drained()
        warm_s = time.perf_counter() - t0
    timeout_s = max(0.1, 5.0 * warm_s)    # recovery fits with 5x margin...
    stall_s = max(0.3 if fast else 0.6,   # ...and the stall blows through it
                  3.0 * timeout_s)
    stalling["stall_s"] = stall_s

    async def scenario():
        frontend = ServeFrontend(eng, request_timeout_s=timeout_s)
        host, port = await frontend.start()
        stalling["on"] = True
        slow = await stream_generate(host, port, [3, 4], max_new_tokens=6)
        stalling["on"] = False                      # stall clears
        # wait for the engine to reap the cancelled request — the pump may
        # still be inside one last stalled step — then prove recovery: the
        # frontend timeout stays armed, and the request completes within it
        deadline = asyncio.get_running_loop().time() + 30.0
        while True:
            health = await get_json(host, port, "/healthz")
            if (health["active"] == 0 and health["pending"] == 0) \
                    or asyncio.get_running_loop().time() > deadline:
                break
            await asyncio.sleep(0.02)
        ok = await stream_generate(host, port, [5, 6], max_new_tokens=1)
        health = await get_json(host, port, "/healthz")
        await frontend.drain()
        await frontend.close()
        return slow, ok, health

    violations: List[str] = []
    crashed = 0
    try:
        slow, ok, health = asyncio.run(scenario())
    except Exception as e:          # noqa: BLE001 - the scenario's verdict
        crashed = 1
        violations.append(f"stack crashed: {type(e).__name__}: {e}")
        slow = ok = None
        health = {}
    if not crashed:
        timed_out = (slow.http_status == 503
                     and slow.summary.get("error") == "timeout") \
            or slow.summary.get("status") == "cancelled"
        if not timed_out:
            violations.append(f"stalled request was not timed out: "
                              f"{slow.http_status} {slow.summary}")
        if slow.http_status == 503 and "retry-after" not in slow.headers:
            violations.append("timeout 503 lacked Retry-After")
        if not (ok.ok and ok.status == "completed"):
            violations.append(f"server did not recover after the stall: "
                              f"{ok.http_status} {ok.summary}")
        if not health.get("pump_alive", False):
            violations.append("pump thread died")
        if health.get("cancelled", 0) < 1:
            violations.append("engine never reaped the cancelled request")
    return ScenarioResult(
        name="slow_decode", ok=not violations, violations=violations,
        details={
            "crashed": crashed, "corrupted_streams": 0,
            "stall_s": stall_s,
            "slow_status": None if crashed else slow.http_status,
            "cancelled": health.get("cancelled"),
            **_flight(eng, violations),
        })


def _scn_client_disconnect(fast: bool, seed: int) -> ScenarioResult:
    """A client vanishes mid-stream.  The engine reaps the abandoned slot,
    ``on_finish`` fires exactly once, and other streams are unaffected."""
    import json as _json

    from ..server import ServeFrontend, get_json, stream_generate

    # long stream + pacing: wide runway for the RST to surface server-side
    # before the request could complete on its own
    max_new = 30 if fast else 60
    eng, guard = _guarded_engine()
    real_step = eng.step

    def paced_step(*a, **kw):       # give the client time to bail mid-stream
        time.sleep(0.01)
        return real_step(*a, **kw)

    eng.step = paced_step

    async def scenario():
        frontend = ServeFrontend(eng)
        host, port = await frontend.start()
        # hand-rolled request so the socket can be dropped after one token
        reader, writer = await asyncio.open_connection(host, port)
        body = _json.dumps({"prompt": [3, 4],
                            "max_new_tokens": max_new}).encode()
        writer.write((f"POST /v1/generate HTTP/1.1\r\nHost: {host}\r\n"
                      f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
        await writer.drain()
        await reader.readline()                     # status line arrived:
        writer.transport.abort()                    # ...and the client dies
        # (abort sends RST so the server's next stream write raises instead
        # of buffering into a half-closed socket)
        # a well-behaved client must still be served while the engine reaps
        # the abandoned request
        survivor = await stream_generate(host, port, [5, 6],
                                         max_new_tokens=3)
        deadline = asyncio.get_running_loop().time() + 30.0
        while True:                 # reap happens on a subsequent step
            health = await get_json(host, port, "/healthz")
            if health["cancelled"] >= 1 or not health["pump_alive"] \
                    or asyncio.get_running_loop().time() > deadline:
                break
            await asyncio.sleep(0.02)
        await frontend.drain()
        await frontend.close()
        return survivor, health

    violations: List[str] = []
    crashed = 0
    try:
        survivor, health = asyncio.run(scenario())
    except Exception as e:          # noqa: BLE001 - the scenario's verdict
        crashed = 1
        violations.append(f"stack crashed: {type(e).__name__}: {e}")
        survivor, health = None, {}
    if not crashed:
        if not health.get("pump_alive", False):
            violations.append("pump thread died after the disconnect")
        if health.get("cancelled", 0) < 1:
            violations.append("disconnected request was never reaped")
        if not (survivor.ok and survivor.status == "completed"
                and len(survivor.tokens) == 3):
            violations.append("survivor stream was damaged by the "
                              "disconnect")
    return ScenarioResult(
        name="client_disconnect", ok=not violations, violations=violations,
        details={
            "crashed": crashed, "corrupted_streams": 0,
            "cancelled": health.get("cancelled"),
            "survivor_tokens": None if crashed else len(survivor.tokens),
            **_flight(eng, violations),
        })


def _scn_overload_shed(fast: bool, seed: int) -> ScenarioResult:
    """Burst into a bounded queue: sheds answer 503 + Retry-After, the
    retrying client backs off deterministically, and the terminal buckets
    balance exactly."""
    from ..server import (RetryPolicy, ServeFrontend, get_json,
                          stream_generate)

    n_req = 8 if fast else 16
    eng, guard = _guarded_engine(policy="priority", max_pending=2)
    real_step = eng.step

    def paced_step(*a, **kw):       # slow service rate so the burst sheds
        time.sleep(0.01)
        return real_step(*a, **kw)

    eng.step = paced_step
    prompts = _prompts(n_req, seed + 3)

    async def scenario():
        frontend = ServeFrontend(eng)
        host, port = await frontend.start()
        warm = await stream_generate(host, port, [3], max_new_tokens=1)
        burst_tasks = [asyncio.create_task(
            stream_generate(host, port, p, max_new_tokens=2))
            for p in prompts]
        await asyncio.sleep(0.05)   # let the burst fill the bounded queue
        # one retrying client arrives into the full queue: its 503s honour
        # Retry-After and back off until the burst clears
        retried_task = asyncio.create_task(stream_generate(
            host, port, [9, 9], max_new_tokens=1,
            retry=RetryPolicy(max_retries=6, backoff_s=0.05, seed=seed)))
        burst = await asyncio.gather(*burst_tasks)
        retried = await retried_task
        health = await get_json(host, port, "/healthz")
        await frontend.drain()
        await frontend.close()
        return warm, burst, retried, health

    violations: List[str] = []
    crashed = 0
    try:
        warm, burst, retried, health = asyncio.run(scenario())
    except Exception as e:          # noqa: BLE001 - the scenario's verdict
        crashed = 1
        violations.append(f"stack crashed: {type(e).__name__}: {e}")
        warm = retried = None
        burst, health = [], {}
    shed = [r for r in burst if r.http_status == 503]
    done = [r for r in burst if r.ok and r.status == "completed"]
    if not crashed:
        if not shed:
            violations.append("burst into a 2-deep queue never shed")
        for r in shed:
            if "retry-after" not in r.headers:
                violations.append("shed 503 lacked Retry-After")
                break
        if len(shed) + len(done) != len(burst):
            violations.append(
                f"terminal buckets do not balance: {len(shed)} shed + "
                f"{len(done)} completed != {len(burst)}")
        if retried is not None and not retried.ok:
            violations.append(f"retrying client never landed "
                              f"({retried.attempts} attempts)")
        if not health.get("pump_alive", False):
            violations.append("pump thread died")
    return ScenarioResult(
        name="overload_shed", ok=not violations, violations=violations,
        details={
            "crashed": crashed, "corrupted_streams": 0,
            "requests": n_req, "shed": len(shed), "completed": len(done),
            "retry_attempts": None if retried is None else retried.attempts,
            "health_shed": health.get("shed"),
            **_flight(eng, violations),
        })


# ---------------------------------------------------------------------------
# Campaign
# ---------------------------------------------------------------------------

SCENARIOS: Dict[str, Callable[[bool, int], ScenarioResult]] = {
    "silent_burst": _scn_silent_burst,
    "rail_droop": _scn_rail_droop,
    "watchdog_delay": _scn_watchdog_delay,
    "slow_decode": _scn_slow_decode,
    "client_disconnect": _scn_client_disconnect,
    "overload_shed": _scn_overload_shed,
}


def run_scenario(name: str, fast: bool = True, seed: int = 0
                 ) -> ScenarioResult:
    try:
        fn = SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; known: "
                       f"{sorted(SCENARIOS)}") from None
    return fn(fast, seed)


def run_campaign(fast: bool = True, seed: int = 0,
                 only: Optional[Sequence[str]] = None) -> ChaosReport:
    """Run the fault campaign; every scenario runs even when an earlier one
    fails (the report carries all verdicts)."""
    ensure_host_callback_capacity()
    names = list(only) if only else list(SCENARIOS)
    t0 = time.perf_counter()
    results = [run_scenario(n, fast=fast, seed=seed) for n in names]
    return ChaosReport(results=results, elapsed_s=time.perf_counter() - t0)


if __name__ == "__main__":
    import argparse
    import json
    import sys

    ap = argparse.ArgumentParser(description="Run the chaos campaign")
    ap.add_argument("--full", action="store_true",
                    help="full-size scenarios (default: fast smoke sizes)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--only", default=None, metavar="NAME[,NAME...]",
                    help=f"subset of {', '.join(SCENARIOS)}")
    ns = ap.parse_args()
    only = ns.only.split(",") if ns.only else None
    report = run_campaign(fast=not ns.full, seed=ns.seed, only=only)
    # lint: allow=RP008 CLI entry point owns stdout; the report IS the output
    print(json.dumps(report.to_dict(), indent=2))
    sys.exit(0 if report.ok else 1)
