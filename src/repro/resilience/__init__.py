"""`repro.resilience`: surviving silent errors on the undervolted array.

Two pieces (PR 8):

* :mod:`repro.resilience.guard` — :class:`GuardedBackend`, an ABFT wrapper
  over any :class:`~repro.backend.base.MatmulBackend` (row/column checksums
  or a Freivalds probe, locate-and-correct, and a retry → rail-heal →
  policy escalation ladder).  Importing this package registers it as the
  ``"guarded"`` backend.
* :mod:`repro.resilience.chaos` — the seeded fault-scenario campaign that
  drives the guarded stack end-to-end through :class:`ServeEngine` and the
  HTTP frontend and asserts graceful degradation.
"""

from .chaos import (ChaosReport, ScenarioResult, SCENARIOS, run_campaign,
                    run_scenario)
from .guard import GuardedBackend, GuardError

__all__ = [
    "GuardedBackend", "GuardError",
    "ChaosReport", "ScenarioResult", "SCENARIOS",
    "run_campaign", "run_scenario",
]
