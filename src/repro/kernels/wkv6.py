"""Pallas TPU kernel: chunked RWKV6 (Finch) WKV recurrence.

The per-token recurrence (ref.wkv6) is matmul-poor; this kernel computes the
chunked form — (chunk x chunk) attention-like matmuls on the MXU with the
cross-chunk state carried in VMEM scratch across sequential grid steps — the
standard TPU mapping for linear-attention recurrences (DESIGN.md hardware
adaptation: per-step scans become MXU tiles).

Grid: (B*H, n_chunks); chunk axis iterates fastest, so the scratch state is
valid per (b, h) and reset at chunk 0.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .tuning import assert_divides, resolve_interpret, select_chunk

EXP_CLAMP = 60.0


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
            state, *, n_chunks: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state[...] = s0_ref[0]

    r = r_ref[0].astype(jnp.float32)          # (ch, p)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w_log = w_ref[0].astype(jnp.float32)      # (ch, p), log decay <= 0
    u = u_ref[0].astype(jnp.float32)          # (p,)
    S = state[...]                            # (p, p)

    ch = r.shape[0]
    lw = jnp.cumsum(w_log, axis=0)            # inclusive
    lw_prev = jnp.concatenate([jnp.zeros_like(lw[:1]), lw[:-1]], axis=0)
    # centre exponents at half the chunk's total decay so exp() stays in
    # f32 range for any chunk length (the A entries are products
    # exp(lw_prev_t - m) * exp(m - lw_s) = exp(lw_prev_t - lw_s) <= 1)
    m = 0.5 * lw[-1:]
    rr = r * jnp.exp(jnp.clip(lw_prev - m, -EXP_CLAMP, EXP_CLAMP))
    kk = k * jnp.exp(jnp.clip(m - lw, -EXP_CLAMP, EXP_CLAMP))
    A = jnp.dot(rr, kk.T, preferred_element_type=jnp.float32)   # (ch, ch)
    mask = jnp.tril(jnp.ones((ch, ch), jnp.float32), k=-1)
    A = A * mask
    diag = jnp.sum(r * u[None, :] * k, axis=-1)                 # (ch,)
    y = jnp.dot(A, v, preferred_element_type=jnp.float32)
    y = y + diag[:, None] * v
    # inter-chunk from carried state (exp(lw_prev) <= 1: no centring needed)
    r_state = r * jnp.exp(jnp.clip(lw_prev, -EXP_CLAMP, 0.0))
    y = y + jnp.dot(r_state, S, preferred_element_type=jnp.float32)

    # state update: S' = diag(prod w) S + sum_s (k_s * decay_to_end) v_s^T
    tail = jnp.exp(jnp.clip(lw[-1:] - lw, -EXP_CLAMP, EXP_CLAMP))
    k_tail = k * tail
    S_new = (S * jnp.exp(jnp.clip(lw[-1], -EXP_CLAMP, 0.0))[:, None]
             + jnp.dot(k_tail.T, v, preferred_element_type=jnp.float32))
    state[...] = S_new
    y_ref[0] = y

    @pl.when(c == n_chunks - 1)
    def _out():
        sout_ref[0] = state[...]


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w_log: jax.Array,
         u: jax.Array, state: jax.Array, *, chunk: Optional[int] = None,
         interpret: Optional[bool] = None):
    """r,k,v,w_log: (b, s, h, p) f32; u: (h, p); state: (b, h, p, p).

    Returns (y (b, s, h, p) f32, final state (b, h, p, p)).

    ``chunk=None`` picks the largest preferred chunk dividing the sequence;
    ``interpret=None`` resolves to the platform-aware tuning default.
    """
    chunk = select_chunk(r.shape[1]) if chunk is None else chunk
    return _wkv6_call(r, k, v, w_log, u, state, chunk=chunk,
                      interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _wkv6_call(r: jax.Array, k: jax.Array, v: jax.Array, w_log: jax.Array,
               u: jax.Array, state: jax.Array, *, chunk: int,
               interpret: bool):
    b, s, h, p = r.shape
    assert_divides(chunk, s, "wkv6 sequence chunk")
    nc = s // chunk
    bh = b * h

    def flat(x):
        return jnp.moveaxis(x, 2, 1).reshape(bh, s, p)     # (bh, s, p)

    rf, kf, vf, wf = (flat(x.astype(jnp.float32)) for x in (r, k, v, w_log))
    uf = jnp.tile(u.astype(jnp.float32), (b, 1))           # (bh, p)
    sf = state.reshape(bh, p, p).astype(jnp.float32)

    seq_spec = pl.BlockSpec((1, chunk, p), lambda i, c: (i, c, 0))
    y, s_out = pl.pallas_call(
        functools.partial(_kernel, n_chunks=nc),
        grid=(bh, nc),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, p), lambda i, c: (i, 0)),
                  pl.BlockSpec((1, p, p), lambda i, c: (i, 0, 0))],
        out_specs=[seq_spec,
                   pl.BlockSpec((1, p, p), lambda i, c: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((bh, s, p), jnp.float32),
                   jax.ShapeDtypeStruct((bh, p, p), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((p, p), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf, sf)
    y = jnp.moveaxis(y.reshape(b, h, s, p), 1, 2)
    return y, s_out.reshape(b, h, p, p)
