"""Pallas TPU kernels (validated in interpret mode vs ref.py oracles):

systolic_mac      voltage-island partitioned matmul + Razor flags (the paper)
razor_matmul      int8 main path + f32 shadow, per-tile mismatch correction
precision_island  per-tile int4/int8/f32 tiers (voltage ladder analogue)
wkv6              chunked RWKV6 recurrence (MXU-mapped)
ssd_chunk         chunked Mamba2 SSD recurrence
ops               jit wrappers + the composed voltage_scaled_matmul flow
"""

from . import ref
from .ops import (precision_mm, razor_mm, ssd_op, systolic_matmul,
                  voltage_scaled_matmul, wkv6_op)
