"""Pallas TPU kernel: voltage-island partitioned systolic matmul with
timing-fault injection + Razor flags (the paper's partitioned MAC array
mapped onto MXU tiles; DESIGN.md Sec. 2b).

Grid: (M/bm, N/bn, K/bk); each (i, j) output tile is one 'FPGA partition
cell' carrying a rail voltage v_map[i, j] and a minimum safe voltage
v_safe[i, j].  Under-volted tiles corrupt their accumulator low bits (the
timing-failure model shared with ref.corrupt_low_bits) and raise a flag —
exactly the per-partition Razor flag the runtime scheme consumes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, vmap_ref, vsafe_ref, out_ref, flag_ref, acc_ref,
            *, keep_bits: int, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.float32),
                            b_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        acc = acc_ref[...]
        fail = vmap_ref[0, 0] < vsafe_ref[0, 0]
        # mantissa truncation = low accumulator bits missing the clock edge
        bits = jax.lax.bitcast_convert_type(acc, jnp.uint32)
        mask = jnp.uint32(0xFFFFFFFF) << jnp.uint32(23 - keep_bits)
        corrupted = jax.lax.bitcast_convert_type(bits & mask, jnp.float32)
        out_ref[...] = jnp.where(fail, corrupted, acc)
        flag_ref[0, 0] = fail.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "keep_bits", "interpret"))
def systolic_mac(a: jax.Array, b: jax.Array, v_map: jax.Array,
                 v_safe: jax.Array, *, block_m: int = 128, block_n: int = 128,
                 block_k: int = 128, keep_bits: int = 8,
                 interpret: bool = True):
    """C = a @ b with per-tile voltage-island fault semantics.

    a: (M, K); b: (K, N); v_map/v_safe: (M/bm, N/bn).
    Returns (C f32 (M, N), flags int32 (M/bm, N/bn)).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    gm, gn, gk = m // block_m, n // block_n, k // block_k
    assert v_map.shape == (gm, gn) == v_safe.shape

    kernel = functools.partial(_kernel, keep_bits=keep_bits, n_k=gk)
    return pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((gm, gn), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b, v_map.astype(jnp.float32), v_safe.astype(jnp.float32))
