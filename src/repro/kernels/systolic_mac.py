"""Pallas TPU kernel: voltage-island partitioned systolic matmul with
timing-fault injection + Razor flags (the paper's partitioned MAC array
mapped onto MXU tiles; DESIGN.md Sec. 2b).

Grid: (M/bm, N/bn, K/bk); each (i, j) output tile is one 'FPGA partition
cell' carrying a rail voltage v_map[i, j] and a minimum safe voltage
v_safe[i, j].  Under-volted tiles corrupt their accumulator low bits (the
timing-failure model shared with ref.corrupt_low_bits) and raise a flag —
exactly the per-partition Razor flag the runtime scheme consumes.

``interpret`` defaults through :func:`repro.kernels.tuning.default_interpret`
(compiled everywhere a Mosaic backend exists, interpreted only on CPU);
``block_m``/``block_n`` default to the partition-cell shape dictated by
``v_map`` and ``block_k`` to the tuning table's preference.  The epilogue
optionally fuses the Razor flag reduction: with ``count_flags=True`` a
running int32 total of fired tiles is accumulated in-kernel, so callers that
only need "how many partitions failed" skip the host-side flag gather.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .tuning import resolve_interpret, select_blocks, sequential_grid


def _kernel(a_ref, b_ref, vmap_ref, vsafe_ref, out_ref, flag_ref, count_ref,
            acc_ref, *, keep_bits: int, n_k: int):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((i == 0) & (j == 0) & (k == 0))
    def _init_count():
        count_ref[0, 0] = 0

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.float32),
                            b_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        acc = acc_ref[...]
        fail = vmap_ref[0, 0] < vsafe_ref[0, 0]
        # mantissa truncation = low accumulator bits missing the clock edge
        bits = jax.lax.bitcast_convert_type(acc, jnp.uint32)
        mask = jnp.uint32(0xFFFFFFFF) << jnp.uint32(23 - keep_bits)
        corrupted = jax.lax.bitcast_convert_type(bits & mask, jnp.float32)
        out_ref[...] = jnp.where(fail, corrupted, acc)
        flag_ref[0, 0] = fail.astype(jnp.int32)
        # fused Razor flag reduction: running total over all (i, j) tiles
        count_ref[0, 0] += fail.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "keep_bits", "interpret"))
def _systolic_mac_call(a, b, v_map, v_safe, *, block_m: int, block_n: int,
                       block_k: int, keep_bits: int, interpret: bool):
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    gm, gn, gk = m // block_m, n // block_n, k // block_k
    assert v_map.shape == (gm, gn) == v_safe.shape

    kernel = functools.partial(_kernel, keep_bits=keep_bits, n_k=gk)
    return pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((gm, gn), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b, v_map.astype(jnp.float32), v_safe.astype(jnp.float32))


def systolic_mac(a: jax.Array, b: jax.Array, v_map: jax.Array,
                 v_safe: jax.Array, *, block_m: Optional[int] = None,
                 block_n: Optional[int] = None, block_k: Optional[int] = None,
                 keep_bits: int = 8, interpret: Optional[bool] = None,
                 count_flags: bool = False):
    """C = a @ b with per-tile voltage-island fault semantics.

    a: (M, K); b: (K, N); v_map/v_safe: (M/bm, N/bn).
    Returns (C f32 (M, N), flags int32 (M/bm, N/bn)); with
    ``count_flags=True`` additionally the in-kernel int32 total of fired
    tiles.  ``block_m``/``block_n`` default to the cell shape ``v_map``
    implies; ``block_k`` comes from the tuning table.
    """
    m, k = a.shape
    n = b.shape[1]
    gm, gn = v_map.shape
    block_m = m // gm if block_m is None else block_m
    block_n = n // gn if block_n is None else block_n
    if block_k is None:
        block_k = select_blocks(m, n, k)[2]
    interpret = resolve_interpret(interpret)
    c, flags, count = _systolic_mac_call(
        a, b, v_map, v_safe, block_m=block_m, block_n=block_n,
        block_k=block_k, keep_bits=keep_bits, interpret=interpret)
    if not count_flags:
        return c, flags
    # the in-kernel accumulator relies on sequential grid execution; on
    # parallel-grid backends (GPU) reduce the flag map on the host instead
    total = count[0, 0] if sequential_grid(interpret) else jnp.sum(flags)
    return c, flags, total
