"""Pallas TPU kernel: precision-island matmul — each output tile computes at
its assigned tier (0=int4, 1=int8, 2=f32), the MXU analogue of
per-partition V_ccint rails (DESIGN.md Sec. 2b mapping table).

Grid: (M/bm, N/bn); the tier map plays the role of the voltage map produced
by the static scheme; the runtime PrecisionController re-tiers from
razor_matmul flags.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tuning import resolve_interpret


def _quant_rows(x, levels: float):
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / levels
    q = jnp.clip(jnp.round(x / scale), -levels, levels)
    return q, scale


def _kernel(a_ref, bt_ref, tier_ref, out_ref):
    a = a_ref[...].astype(jnp.float32)
    bt = bt_ref[...].astype(jnp.float32)
    tier = tier_ref[0, 0]
    f32 = jnp.dot(a, bt.T, preferred_element_type=jnp.float32)
    qa8, sa8 = _quant_rows(a, 127.0)
    qb8, sb8 = _quant_rows(bt, 127.0)
    i8 = jnp.dot(qa8, qb8.T, preferred_element_type=jnp.float32) * sa8 * sb8.T
    qa4, sa4 = _quant_rows(a, 7.0)
    qb4, sb4 = _quant_rows(bt, 7.0)
    i4 = jnp.dot(qa4, qb4.T, preferred_element_type=jnp.float32) * sa4 * sb4.T
    out_ref[...] = jnp.where(tier == 0, i4, jnp.where(tier == 1, i8, f32))


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def _precision_island_call(a, b, tiers, *, block_m: int, block_n: int,
                           interpret: bool) -> jax.Array:
    m, k = a.shape
    _, n = b.shape
    gm, gn = m // block_m, n // block_n
    assert tiers.shape == (gm, gn)
    return pl.pallas_call(
        _kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b.T, tiers.astype(jnp.int32))


def precision_island(a: jax.Array, b: jax.Array, tiers: jax.Array, *,
                     block_m: Optional[int] = None,
                     block_n: Optional[int] = None,
                     interpret: Optional[bool] = None) -> jax.Array:
    """Tiered matmul; block sizes default to the island shape ``tiers``
    implies, ``interpret`` to the platform-aware tuning default."""
    m = a.shape[0]
    n = b.shape[1]
    gm, gn = tiers.shape
    block_m = m // gm if block_m is None else block_m
    block_n = n // gn if block_n is None else block_n
    return _precision_island_call(a, b, tiers, block_m=block_m,
                                  block_n=block_n,
                                  interpret=resolve_interpret(interpret))
