"""jit'd wrappers over the Pallas kernels + the composed paper-flow op.

``voltage_scaled_matmul`` is the end-to-end TPU mapping of the paper: static
tier/voltage assignment over weight tiles -> partitioned kernel execution ->
Razor flags -> one runtime (Algorithm 2) adjustment step — usable as a
drop-in matmul for experiments.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.precision import (ENERGY_PER_MAC, TIERS, PrecisionController,
                              static_tier_assignment, tile_headroom)
from ..core.voltage import static_voltage_scaling
from .precision_island import precision_island
from .razor_matmul import razor_matmul
from .ssd_chunk import ssd_chunk
from .systolic_mac import systolic_mac
from .tuning import default_interpret as _default_interpret
from .tuning import select_blocks, select_chunk, select_square_block
from .wkv6 import wkv6

# Every kernel now resolves ``interpret=None`` through
# ``tuning.default_interpret`` itself (compiled off-CPU, interpreted on CPU)
# and picks block/chunk sizes from the tuning tables, so these wrappers are
# plain aliases kept for the established ``ops.*`` call sites.


def systolic_matmul(a, b, v_map, v_safe, **kw):
    return systolic_mac(a, b, v_map, v_safe, **kw)


def razor_mm(a, b, tol: float = 0.05, **kw):
    return razor_matmul(a, b, tol=tol, **kw)


def precision_mm(a, b, tiers, **kw):
    return precision_island(a, b, tiers, **kw)


def wkv6_op(r, k, v, w_log, u, state, chunk: Optional[int] = None, **kw):
    return wkv6(r, k, v, w_log, u, state, chunk=chunk, **kw)


def ssd_op(x, dt, A_log, B, C, D, state, chunk: Optional[int] = None, **kw):
    return ssd_chunk(x, dt, A_log, B, C, D, state, chunk=chunk, **kw)


# ---------------------------------------------------------------------------
# Composed paper flow on one GEMM
# ---------------------------------------------------------------------------


def voltage_scaled_matmul(a: jax.Array, b: jax.Array, *,
                          block: Optional[int] = None,
                          n_partitions: int = 4,
                          v_min: float = 1.0, v_crash: float = 0.7,
                          interpret: Optional[bool] = None
                          ) -> Tuple[jax.Array, dict]:
    """Paper flow on a single GEMM.

    1. 'Timing extraction': per-tile quantization headroom of ``b`` (the
       resident weights — the slack analogue).
    2. Clustering/static scheme: Algorithm 1 bands headroom into
       ``n_partitions`` voltages.
    3. Partitioned execution: systolic_mac with the derived voltage map;
       min-safe voltage per tile derived from headroom (less headroom ->
       needs more voltage).
    4. Razor flags -> one Algorithm-2 adjustment -> corrected rerun.

    Returns (C, info) where info carries voltages, flags and the modeled
    energy ratio vs an all-nominal run.
    """
    interpret = _default_interpret() if interpret is None else interpret
    m, k = a.shape
    _, n = b.shape
    block = select_square_block(m, n) if block is None else block
    gm, gn = m // block, n // block

    head = tile_headroom(np.asarray(b, np.float32), tile=k)  # (1, gn) over cols
    head_cols = tile_headroom(np.asarray(b, np.float32).T, tile=block)
    # per output tile: headroom of the b-column block feeding it
    h_tile = np.tile(head_cols[:, :1].T if head_cols.shape[1] == 1 else
                     head_cols.mean(1, keepdims=True).T, (gm, 1))
    h_tile = np.broadcast_to(h_tile[:gm, :gn], (gm, gn))

    bands = static_voltage_scaling(v_min, v_crash, n_partitions)
    tiers = static_tier_assignment(h_tile, n_tiers=n_partitions)
    # tier 0 = most headroom -> lowest voltage
    v_map = np.asarray(bands)[tiers]
    lo, hi = h_tile.min(), h_tile.max()
    frac = (h_tile - lo) / max(hi - lo, 1e-9)
    v_safe = v_crash + (1 - frac) * (v_min - v_crash) * 0.9

    c, flags, n_fired = systolic_mac(
        a, b, jnp.asarray(v_map), jnp.asarray(v_safe), block_m=block,
        block_n=block, block_k=min(block, k), interpret=interpret,
        count_flags=True)
    # Algorithm 2: bump failed partitions one step, clean ones down one step
    v_s = (v_min - v_crash) / n_partitions
    v_adj = np.where(np.asarray(flags) > 0, v_map + v_s,
                     np.maximum(v_map - v_s, v_crash))
    c2, flags2, n_fired2 = systolic_mac(
        a, b, jnp.asarray(v_adj), jnp.asarray(v_safe), block_m=block,
        block_n=block, block_k=min(block, k), interpret=interpret,
        count_flags=True)
    energy_ratio = float(np.mean((v_adj / v_min) ** 2))
    return c2, {
        "v_static": v_map, "v_runtime": v_adj,
        "flags_static": np.asarray(flags), "flags_runtime": np.asarray(flags2),
        # fused in-kernel flag reductions (no host-side gather needed)
        "n_fired_static": int(n_fired), "n_fired_runtime": int(n_fired2),
        "energy_ratio_vs_nominal": energy_ratio,
    }
