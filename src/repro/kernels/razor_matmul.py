"""Pallas TPU kernel: Razor double-sampled matmul.

Main path = int8 x int8 -> int32 (the cheap near-threshold path); shadow
path = f32 (the delayed shadow register).  Per output tile the kernel emits a
mismatch flag (relative Frobenius error > tol) and — like Razor's replay —
*corrects* flagged tiles to the shadow value.  This doubles the multiplier
count exactly as the paper notes for Razor (Sec. II-E); the flags feed
core.precision.PrecisionController (Algorithm 2 on precision tiers).

Grid: (M/bm, N/bn); K is loaded whole per tile (rows fit VMEM for K <= ~4k).

The epilogue fuses the flag reduction: a running int32 count of fired tiles
accumulates across the grid, so callers needing only the totals
(``count_flags=True``) avoid a separate host-side gather over the flag map.
``interpret`` defaults through :func:`repro.kernels.tuning.default_interpret`
and block sizes through :func:`repro.kernels.tuning.select_blocks`.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tuning import resolve_interpret, select_blocks, sequential_grid


def _quant_rows(x):
    """Symmetric per-row int8 quantization (row = last axis)."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q, scale


def _kernel(a_ref, bt_ref, out_ref, flag_ref, rel_ref, count_ref, *,
            tol: float):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init_count():
        count_ref[0, 0] = 0

    a = a_ref[...].astype(jnp.float32)           # (bm, K)
    bt = bt_ref[...].astype(jnp.float32)         # (bn, K)  (B pre-transposed)
    qa, sa = _quant_rows(a)
    qb, sb = _quant_rows(bt)
    main = jnp.dot(qa, qb.T, preferred_element_type=jnp.float32) * sa * sb.T
    shadow = jnp.dot(a, bt.T, preferred_element_type=jnp.float32)
    err = jnp.sqrt(jnp.sum((main - shadow) ** 2))
    refn = jnp.sqrt(jnp.sum(shadow ** 2)) + 1e-12
    rel = err / refn
    fired = rel > tol
    # fused epilogue: correction + flag + running flag reduction in one pass
    out_ref[...] = jnp.where(fired, shadow, main)
    flag_ref[0, 0] = fired.astype(jnp.int32)
    rel_ref[0, 0] = rel
    count_ref[0, 0] += fired.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "tol",
                                             "interpret"))
def _razor_matmul_call(a, b, *, tol: float, block_m: int, block_n: int,
                       interpret: bool):
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % block_m == 0 and n % block_n == 0
    gm, gn = m // block_m, n // block_n
    bt = b.T                                      # (n, k): rows quantize over k
    kernel = functools.partial(_kernel, tol=tol)
    return pl.pallas_call(
        kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((gm, gn), jnp.int32),
            jax.ShapeDtypeStruct((gm, gn), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(a, bt)


def razor_matmul(a: jax.Array, b: jax.Array, *, tol: float = 0.05,
                 block_m: Optional[int] = None, block_n: Optional[int] = None,
                 interpret: Optional[bool] = None, count_flags: bool = False):
    """Returns (C f32 (M, N) corrected, flags int32 (gm, gn), rel (gm, gn));
    with ``count_flags=True`` additionally the fused int32 fired-tile total."""
    m, _ = a.shape
    n = b.shape[1]
    if block_m is None or block_n is None:
        bm, bn = select_blocks(m, n)
        block_m = bm if block_m is None else block_m
        block_n = bn if block_n is None else block_n
    interpret = resolve_interpret(interpret)
    c, flags, rel, count = _razor_matmul_call(
        a, b, tol=tol, block_m=block_m, block_n=block_n, interpret=interpret)
    if not count_flags:
        return c, flags, rel
    # in-kernel accumulation needs a sequential grid; on parallel-grid
    # backends (GPU) reduce the flag map on the host instead
    total = count[0, 0] if sequential_grid(interpret) else jnp.sum(flags)
    return c, flags, rel, total
