"""Platform-aware compile defaults + block-size selection for the Pallas
kernels.

Two decisions every kernel wrapper needs are centralized here:

* ``default_interpret()`` — whether ``pallas_call`` should run in interpret
  mode.  Interpret mode is required on CPU (no Mosaic backend) but must NOT
  be the default on TPU, where it silently turns compiled kernels into a
  Python-level emulator.  Kernels take ``interpret=None`` and resolve it
  through this function, so off-CPU callers get the real compiled path.

* ``select_blocks(m, n, k)`` — block sizes chosen from the problem shape via
  an overridable preference table.  TPU tiling wants the last dimension a
  multiple of 128 and the sublane dimension a multiple of 8 (f32), so the
  table prefers MXU-shaped 128/256 blocks and degrades to the largest
  divisor of the axis; tiny problems fall back to whole-axis blocks.  Pass a
  custom ``table`` (or mutate :data:`BLOCK_TABLE`) to pin different shapes —
  e.g. benchmark-tuned blocks for a specific chip generation.
"""

from __future__ import annotations

import warnings
from typing import Dict, Optional, Sequence, Tuple

import jax

#: Preferred block sizes per grid axis, best first.  The first entry that
#: divides the axis wins; if none divides it, the full axis is used (the
#: kernels assert divisibility, so a whole axis is always valid).  128 leads
#: every axis — the MXU's native tile edge, and the established default of
#: the kernels' former fixed ``block_*=128`` signatures.
BLOCK_TABLE: Dict[str, Tuple[int, ...]] = {
    "m": (128, 64, 32, 16, 8),
    "n": (128, 64, 32, 16, 8),
    "k": (128, 64, 32, 16, 8),
}

#: Preferred sequence-chunk lengths for the scan kernels (wkv6 / ssd_chunk).
CHUNK_TABLE: Tuple[int, ...] = (128, 64, 32, 16, 8)


#: One-shot guard for the interpret-fallback warning below.
_INTERPRET_WARNED = False


def default_interpret() -> bool:
    """Interpret Pallas kernels only where no compiled backend exists.

    The CPU fallback is announced once per process (via :mod:`warnings`):
    interpret mode is correct but runs the kernels as a Python-level
    emulator, so its timings must never be mistaken for compiled numbers.
    """
    global _INTERPRET_WARNED
    on_cpu = jax.default_backend() == "cpu"
    if on_cpu and not _INTERPRET_WARNED:
        _INTERPRET_WARNED = True
        warnings.warn(
            "no compiled Pallas backend on the CPU platform: kernels fall "
            "back to interpret mode (correct, but a Python-level emulator — "
            "not representative of compiled TPU performance)",
            RuntimeWarning, stacklevel=2)
    return on_cpu


def resolve_interpret(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def sequential_grid(interpret: bool) -> bool:
    """Whether ``pallas_call`` grid cells execute one after another.

    True in interpret mode and on TPU (Mosaic iterates the grid on one
    core); False on GPU, where Triton launches grid cells in parallel and
    cross-cell accumulator outputs (the fused flag counts) would race.
    """
    return bool(interpret) or jax.default_backend() == "tpu"


def _pick(size: int, prefs: Sequence[int]) -> int:
    for b in prefs:
        if b <= size and size % b == 0:
            return b
    return size


def select_blocks(m: int, n: int, k: Optional[int] = None,
                  table: Optional[Dict[str, Sequence[int]]] = None
                  ) -> Tuple[int, ...]:
    """(block_m, block_n[, block_k]) for an (m, n[, k]) problem.

    Every returned block divides its axis, so the kernels' divisibility
    asserts always hold.
    """
    t = dict(BLOCK_TABLE)
    if table:
        t.update(table)
    out = (_pick(m, t["m"]), _pick(n, t["n"]))
    return out if k is None else out + (_pick(k, t["k"]),)


def select_chunk(seq_len: int, prefs: Sequence[int] = CHUNK_TABLE) -> int:
    """Largest preferred chunk length dividing ``seq_len``."""
    return _pick(seq_len, prefs)


def select_square_block(*dims: int,
                        prefs: Optional[Sequence[int]] = None) -> int:
    """Largest preferred block edge dividing *every* given dim.

    The square-tile analogue of :func:`select_blocks` for callers whose tile
    grid must share one edge across axes (voltage maps, flag grids, the
    pure-jnp oracles).  Falls back to the gcd of the dims when no table
    entry divides them all, so the result always tiles exactly.
    """
    import math

    prefs = BLOCK_TABLE["m"] if prefs is None else prefs
    for b in prefs:
        if all(b <= d and d % b == 0 for d in dims):
            return b
    g = 0
    for d in dims:
        g = math.gcd(g, d)
    return max(g, 1)


def assert_divides(block: int, dim: int, what: str) -> None:
    """Fail fast (and informatively) when a block does not tile its axis."""
    if block <= 0 or dim % block != 0:
        raise ValueError(
            f"{what}: block {block} does not divide axis of size {dim} — "
            f"pass block/chunk=None to pick from the tuning tables")
