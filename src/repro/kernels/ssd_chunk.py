"""Pallas TPU kernel: chunked Mamba2 SSD recurrence.

Same mapping strategy as wkv6: the per-token state recurrence (ref.ssd) is
computed in chunk-parallel matmul form; the (n, p) cross-chunk state rides in
VMEM scratch across the sequential chunk grid axis.

Grid: (B*H, n_chunks).  B/C are shared across heads (n_groups=1) and arrive
pre-broadcast from ops.py.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .tuning import assert_divides, resolve_interpret, select_chunk

EXP_CLAMP = 30.0


def _kernel(x_ref, dt_ref, b_ref, c_ref, alog_ref, d_ref, s0_ref,
            y_ref, sout_ref, state, *, n_chunks: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state[...] = s0_ref[0]

    x = x_ref[0].astype(jnp.float32)          # (ch, p)
    dt = dt_ref[0].astype(jnp.float32)        # (ch,)
    B = b_ref[0].astype(jnp.float32)          # (ch, n)
    C = c_ref[0].astype(jnp.float32)          # (ch, n)
    a = -jnp.exp(alog_ref[0])                 # scalar
    D = d_ref[0]                              # scalar
    S = state[...]                            # (n, p)

    da = dt * a                               # (ch,) log decay, <= 0
    cum = jnp.cumsum(da)                      # inclusive
    scores = jnp.dot(C, B.T, preferred_element_type=jnp.float32)  # (ch, ch)
    decay = jnp.exp(jnp.clip(cum[:, None] - cum[None, :], -EXP_CLAMP,
                             EXP_CLAMP))
    ch = x.shape[0]
    mask = jnp.tril(jnp.ones((ch, ch), jnp.float32))              # inclusive
    W = scores * decay * mask
    xdt = x * dt[:, None]
    y = jnp.dot(W, xdt, preferred_element_type=jnp.float32)
    # inter-chunk: C_t . S_in * exp(cum_t)
    y = y + jnp.dot(C, S, preferred_element_type=jnp.float32) \
        * jnp.exp(jnp.clip(cum, -EXP_CLAMP, 0.0))[:, None]
    y = y + D * x
    y_ref[0] = y

    # state: S' = S * exp(cum_end) + sum_s exp(cum_end - cum_s) B_s (x) xdt_s
    tail = jnp.exp(jnp.clip(cum[-1] - cum, -EXP_CLAMP, EXP_CLAMP))
    B_tail = B * tail[:, None]
    state[...] = (S * jnp.exp(jnp.clip(cum[-1], -EXP_CLAMP, 0.0))
                  + jnp.dot(B_tail.T, xdt, preferred_element_type=jnp.float32))

    @pl.when(c_idx == n_chunks - 1)
    def _out():
        sout_ref[0] = state[...]


def ssd_chunk(x: jax.Array, dt: jax.Array, A_log: jax.Array, B: jax.Array,
              C: jax.Array, D: jax.Array, state: jax.Array, *,
              chunk: Optional[int] = None, interpret: Optional[bool] = None):
    """x: (b, s, h, p); dt: (b, s, h); A_log, D: (h,); B, C: (b, s, n);
    state: (b, h, n, p).  Returns (y (b, s, h, p), final_state).

    ``chunk=None`` picks the largest preferred chunk dividing the sequence;
    ``interpret=None`` resolves to the platform-aware tuning default."""
    chunk = select_chunk(x.shape[1]) if chunk is None else chunk
    return _ssd_chunk_call(x, dt, A_log, B, C, D, state, chunk=chunk,
                           interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_chunk_call(x: jax.Array, dt: jax.Array, A_log: jax.Array,
                    B: jax.Array, C: jax.Array, D: jax.Array,
                    state: jax.Array, *, chunk: int, interpret: bool):
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert_divides(chunk, s, "ssd_chunk sequence chunk")
    nc = s // chunk
    bh = b * h

    xf = jnp.moveaxis(x.astype(jnp.float32), 2, 1).reshape(bh, s, p)
    dtf = jnp.moveaxis(dt.astype(jnp.float32), 2, 1).reshape(bh, s)
    Bf = jnp.repeat(B.astype(jnp.float32), h, axis=0).reshape(b, h, s, n) \
        .reshape(bh, s, n)
    Cf = jnp.repeat(C.astype(jnp.float32), h, axis=0).reshape(b, h, s, n) \
        .reshape(bh, s, n)
    af = jnp.tile(A_log.astype(jnp.float32), b)            # (bh,)
    df = jnp.tile(D.astype(jnp.float32), b)
    sf = state.reshape(bh, n, p).astype(jnp.float32)

    y, s_out = pl.pallas_call(
        functools.partial(_kernel, n_chunks=nc),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk), lambda i, c: (i, c)),
            pl.BlockSpec((1, chunk, n), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1,), lambda i, c: (i,)),
            pl.BlockSpec((1,), lambda i, c: (i,)),
            pl.BlockSpec((1, n, p), lambda i, c: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, n, p), lambda i, c: (i, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bh, s, p), jnp.float32),
                   jax.ShapeDtypeStruct((bh, n, p), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, Bf, Cf, af, df, sf)
    y = jnp.moveaxis(y.reshape(b, h, s, p), 1, 2)
    return y, s_out.reshape(b, h, n, p)
