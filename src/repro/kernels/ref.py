"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Semantics shared between kernel and oracle are defined HERE; kernels must
reproduce these bit-for-bit up to dtype tolerance.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .tuning import select_square_block

EXP_CLAMP = 30.0


# ---------------------------------------------------------------------------
# systolic_mac: voltage-island partitioned matmul with timing-fault injection
# ---------------------------------------------------------------------------


def corrupt_low_bits(x: jax.Array, keep_bits: int = 8) -> jax.Array:
    """Timing-failure corruption model: the accumulator's low mantissa bits
    miss the clock edge — emulated by mantissa truncation of the f32 result."""
    xi = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    mask = jnp.uint32(0xFFFFFFFF) << jnp.uint32(23 - keep_bits)
    return jax.lax.bitcast_convert_type(xi & mask, jnp.float32)


def systolic_mac(a: jax.Array, b: jax.Array, v_map: jax.Array,
                 v_safe: jax.Array, block: Optional[int] = None,
                 keep_bits: int = 8) -> Tuple[jax.Array, jax.Array]:
    """C = a @ b on a voltage-island-partitioned MAC grid.

    v_map / v_safe: (M/block, N/block) per-tile rail voltage and minimum safe
    voltage.  Tiles with v < v_safe suffer the corruption model and raise
    their Razor flag.  Returns (C (M, N) f32, flags (gm, gn) int32).
    """
    m, k = a.shape
    k2, n = b.shape
    block = select_square_block(m, n) if block is None else block
    assert k == k2 and m % block == 0 and n % block == 0
    c = (a.astype(jnp.float32) @ b.astype(jnp.float32))
    gm, gn = m // block, n // block
    fail = (v_map < v_safe)
    c_t = c.reshape(gm, block, gn, block)
    corrupted = corrupt_low_bits(c_t, keep_bits)
    out = jnp.where(fail[:, None, :, None], corrupted, c_t)
    return out.reshape(m, n), fail.astype(jnp.int32)


# ---------------------------------------------------------------------------
# razor_matmul: low-precision main path + full-precision shadow + flags
# ---------------------------------------------------------------------------


def quantize_sym_i8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-row int8 quantization (row = last-axis vectors)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def razor_matmul(a: jax.Array, b: jax.Array, tol: float = 0.05,
                 block: Optional[int] = None
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Razor-style double-sampled matmul.

    Main path: int8xint8->int32 (the near-threshold 'fast but risky' path).
    Shadow path: f32 (the delayed-clock shadow register).
    Per (block x block) tile: flag = relative Frobenius error > tol; flagged
    tiles are *corrected* to the shadow value (razor replay semantics).
    Returns (C (M,N) f32 corrected, flags (gm, gn) int32, rel_err (gm, gn)).
    """
    m, k = a.shape
    _, n = b.shape
    block = select_square_block(m, n) if block is None else block
    assert m % block == 0 and n % block == 0
    qa, sa = quantize_sym_i8(a)                       # (m,k), (m,1)
    qb, sb = quantize_sym_i8(b.T)                     # (n,k), (n,1)
    main = (qa.astype(jnp.int32) @ qb.astype(jnp.int32).T).astype(jnp.float32)
    main = main * sa * sb.T
    shadow = a.astype(jnp.float32) @ b.astype(jnp.float32)
    gm, gn = m // block, n // block
    mt = main.reshape(gm, block, gn, block)
    st = shadow.reshape(gm, block, gn, block)
    err = jnp.sqrt(jnp.sum((mt - st) ** 2, axis=(1, 3)))
    ref = jnp.sqrt(jnp.sum(st ** 2, axis=(1, 3))) + 1e-12
    rel = err / ref
    flags = (rel > tol).astype(jnp.int32)
    out = jnp.where(flags[:, None, :, None] == 1, st, mt)
    return out.reshape(m, n), flags, rel


# ---------------------------------------------------------------------------
# precision_island: per-tile precision-tier matmul
# ---------------------------------------------------------------------------


def quantize_sym_i4(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 7.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -7, 7)
    return q.astype(jnp.int8), scale


def _tile_matmul_at_tier(at: jax.Array, bt: jax.Array, tier: jax.Array):
    """at: (bm, k); bt: (k, bn); tier scalar 0=int4 1=int8 2=bf16/f32."""
    f32 = at.astype(jnp.float32) @ bt.astype(jnp.float32)
    qa8, sa8 = quantize_sym_i8(at)
    qb8, sb8 = quantize_sym_i8(bt.T)
    i8 = (qa8.astype(jnp.int32) @ qb8.astype(jnp.int32).T).astype(jnp.float32) \
        * sa8 * sb8.T
    qa4, sa4 = quantize_sym_i4(at)
    qb4, sb4 = quantize_sym_i4(bt.T)
    i4 = (qa4.astype(jnp.int32) @ qb4.astype(jnp.int32).T).astype(jnp.float32) \
        * sa4 * sb4.T
    return jnp.where(tier == 0, i4, jnp.where(tier == 1, i8, f32))


def precision_island(a: jax.Array, b: jax.Array, tiers: jax.Array,
                     block: Optional[int] = None) -> jax.Array:
    """C = a @ b where each (block x block) output tile computes at its
    assigned tier (0=int4, 1=int8, 2=full f32) — the TPU analogue of
    per-partition V_ccint (DESIGN.md Sec. 2b)."""
    m, k = a.shape
    _, n = b.shape
    block = select_square_block(m, n) if block is None else block
    gm, gn = m // block, n // block
    rows = []
    for i in range(gm):
        cols = []
        at = a[i * block:(i + 1) * block]
        for j in range(gn):
            bt = b[:, j * block:(j + 1) * block]
            cols.append(_tile_matmul_at_tier(at, bt, tiers[i, j]))
        rows.append(jnp.concatenate(cols, axis=1))
    return jnp.concatenate(rows, axis=0)


# ---------------------------------------------------------------------------
# wkv6: RWKV6 recurrence (naive scan oracle)
# ---------------------------------------------------------------------------


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w_log: jax.Array,
         u: jax.Array, state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Naive per-token recurrence.  r,k,v,w_log: (b, s, h, p); u: (h, p);
    state: (b, h, p, p).  y_t = r_t.(S + (u*k_t) v_t^T); S' = diag(w)S + k v^T.
    """

    def step(S, xs):
        r_t, k_t, v_t, w_t = xs
        kv = jnp.einsum("bhp,bhq->bhpq", k_t, v_t)
        y = jnp.einsum("bhp,bhpq->bhq", r_t, S + u[None, :, :, None] * kv)
        S = S * jnp.exp(w_t)[..., None] + kv
        return S, y

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (r, k, v, w_log))
    S, ys = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), S


# ---------------------------------------------------------------------------
# ssd: Mamba2 state-space recurrence (naive scan oracle)
# ---------------------------------------------------------------------------


def ssd(x: jax.Array, dt: jax.Array, A_log: jax.Array, B: jax.Array,
        C: jax.Array, D: jax.Array,
        state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Naive SSD recurrence.  x: (b, s, h, p); dt: (b, s, h); B, C: (b, s, n);
    A_log, D: (h,); state: (b, h, n, p).
      h' = h * exp(dt * -exp(A_log)) + dt * B (x) x ; y = C . h' + D * x
    """

    def step(S, xs):
        x_t, dt_t, B_t, C_t = xs
        da = jnp.exp(jnp.clip(dt_t * -jnp.exp(A_log), -EXP_CLAMP, 0.0))
        S = (S * da[:, :, None, None]
             + jnp.einsum("bn,bh,bhp->bhnp", B_t, dt_t, x_t))
        y = jnp.einsum("bn,bhnp->bhp", C_t, S) + D[None, :, None] * x_t
        return S, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (x, dt, B, C))
    S, ys = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), S
